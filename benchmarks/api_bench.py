"""Auto-dispatch benchmark: ``method="auto"`` vs each explicit backend.

Runs the :func:`repro.solve` front door on a host-ordered web graph
(the block-compressible family the BSR tiers exploit — same generator
as engine_bench) and times every registry backend plus the auto
dispatcher, so the registry's priority table can be audited against
measured wall time.  Emits ``BENCH_api.json`` (schema-guarded by
``python -m benchmarks.run --smoke``).

  PYTHONPATH=src python -m benchmarks.api_bench            # N=2^16
  PYTHONPATH=src python -m benchmarks.api_bench --smoke    # tiny CI run
"""
from __future__ import annotations

import json
import sys
import time

METHODS = (
    "auto",
    "sequential",
    "frontier:segment_sum",
    "frontier:pallas",
    "engine:chunk",
    "engine:bsr",
    "simulator",
)


def run_method(problem, method: str, k_sim: int = 8) -> dict:
    import repro

    opts = repro.SolverOptions(
        k=k_sim if method == "simulator" else None,
        record_every=200,
    )
    t0 = time.perf_counter()
    rep = repro.solve(problem, method=method, options=opts)
    wall = time.perf_counter() - t0
    return {
        "method": method,
        "resolved": rep.method,
        "n": problem.n,
        "n_edges": problem.n_edges,
        "wall_s": round(wall, 3),
        "n_ops": int(rep.n_ops),
        "cost_iterations": round(rep.cost_iterations, 3),
        "residual": float(rep.residual),
        "converged": bool(rep.converged),
    }


def main(smoke: bool = False, out_path: str = "BENCH_api.json",
         n: int | None = None) -> dict:
    import jax

    import repro
    from repro.core import host_block_graph

    n = n if n is not None else (2**10 if smoke else 2**16)
    methods = (
        ("auto", "frontier:segment_sum", "engine:chunk", "simulator")
        if smoke else METHODS
    )
    g = host_block_graph(n, host_size=128, links_per_node=8.0,
                         intra_frac=0.92, span_hosts=2, seed=1)
    problem = repro.Problem.pagerank(g, target_error=1.0 / n)
    print(f"[api bench] N={n} L={g.n_edges} "
          f"target_error={problem.target_error:.2e} "
          f"platform={jax.default_backend()}")
    rows = []
    for method in methods:
        try:
            row = run_method(problem, method)
        except Exception as e:  # e.g. k/device constraints on this host
            row = {"method": method, "n": n, "skipped": str(e)}
        rows.append(row)
        if "skipped" in row:
            print(f"  {method:22s} skipped: {row['skipped']}")
        else:
            tag = (f" -> {row['resolved']}" if method == "auto" else "")
            print(f"  {method:22s}{tag:24s} {row['wall_s']:8.2f}s  "
                  f"cost={row['cost_iterations']:7.2f}  "
                  f"converged={row['converged']}")
    from benchmarks._meta import std_meta

    payload = {
        "meta": std_meta(
            "api_auto_dispatch",
            seed=1,
            n=n,
            graph="host_block_graph",
            target_error=problem.target_error,
            backends_registered=sorted(repro.list_backends()),
        ),
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[api bench] wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
