"""Paper Figures 1–4 and 15–18: convergence curves and partition evolution.

* Fig 1/2: K = 2, N = 1000, initial splits 250/750, 500/500, 750/250 —
  convergence of r_k + s_k per PID, with the exchange cost neglected
  (charge_exchange=False, Fig 1) vs charged (Fig 2).
* Fig 3/4: dynamic partition from the 750/250 start — per-PID curves
  converge together; partition sizes evolve (Z = 1 for fast adaptation).
* Fig 15–18: global convergence (upper bound on L1 distance) for
  K ∈ {2..64}, N = 10000 web-like graph, all four strategies.

Outputs CSV curves under results/paper/.
"""
from __future__ import annotations

import csv
import os
from typing import List, Optional

import numpy as np

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    pagerank_system,
    power_law_graph,
    webgraph_like,
)
from repro.core.partition import uniform_partition

OUT_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "results", "paper")
)


def _write_curves(path, header, rows):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)


def _sim_with_split(p, b, split: float, charge: bool, dynamic: bool,
                    z: int = 10, max_steps=200_000):
    """K=2 with an unbalanced initial partition (split = frac in Ω_1)."""
    cfg = SimulatorConfig(
        k=2, target_error=1.0 / p.n, eps=0.15, dynamic=dynamic,
        charge_exchange=charge, record_every=1, z=z, max_steps=max_steps,
    )
    sim = DistributedSimulator(p, b, cfg)
    cut = int(p.n * split)
    sim.sets = [np.arange(cut), np.arange(cut, p.n)]
    sim.owner[: cut] = 0
    sim.owner[cut:] = 1
    fw = np.abs(sim.f) * sim.weights
    sim.t_k = np.array([
        fw[s].max() * 2.0 + 1e-300 if s.size else 1.0 for s in sim.sets
    ])
    return sim.run()


def fig_1_2(n: int = 1000, seed: int = 0):
    g = power_law_graph(n, seed=seed)
    p, b = pagerank_system(g)
    for charge, name in ((False, "fig1"), (True, "fig2")):
        rows = []
        for split in (0.25, 0.5, 0.75):
            res = _sim_with_split(p, b, split, charge, dynamic=False)
            iters = res.hist_steps * (n // 2) / max(g.n_edges, 1)
            for it, rs in zip(iters, res.hist_rs):
                rows.append([f"{split:.2f}", f"{it:.4f}",
                             f"{rs[0]:.6e}", f"{rs[1]:.6e}"])
        _write_curves(os.path.join(OUT_DIR, f"{name}.csv"),
                      ["split", "iterations", "r_s_pid1", "r_s_pid2"], rows)
        print(f"[{name}] charge={charge}: {len(rows)} curve points")


def fig_3_4(n: int = 1000, seed: int = 0):
    g = power_law_graph(n, seed=seed)
    p, b = pagerank_system(g)
    res = _sim_with_split(p, b, 0.75, charge=True, dynamic=True, z=1)
    iters = res.hist_steps * (n // 2) / max(g.n_edges, 1)
    rows = [
        [f"{it:.4f}", f"{rs[0]:.6e}", f"{rs[1]:.6e}", int(sz[0]), int(sz[1])]
        for it, rs, sz in zip(iters, res.hist_rs, res.hist_sizes)
    ]
    _write_curves(os.path.join(OUT_DIR, "fig3_4.csv"),
                  ["iterations", "r_s_pid1", "r_s_pid2",
                   "size_pid1", "size_pid2"], rows)
    print(f"[fig3_4] dynamic from 750/250: moves={res.n_moves} "
          f"final sizes={res.hist_sizes[-1].tolist()}")
    return res


def fig_global(n: int = 10000, ks=(2, 8, 32), seed: int = 1,
               max_steps: int = 40_000):
    g = webgraph_like(n, seed=seed)
    p, b = pagerank_system(g)
    rows = []
    for k in ks:
        for part in ("uniform", "cb"):
            for dyn in (False, True):
                cfg = SimulatorConfig(
                    k=k, target_error=1.0 / n, eps=0.15, partition=part,
                    dynamic=dyn, mode="batch", record_every=5,
                    max_steps=max_steps,
                )
                res = DistributedSimulator(p, b, cfg).run()
                iters = res.hist_steps * (n // k) / max(g.n_edges, 1)
                label = f"K{k}_{part}{'_dyn' if dyn else ''}"
                for it, gres in zip(iters, res.hist_residual):
                    rows.append([label, f"{it:.4f}", f"{gres:.6e}"])
                print(f"[fig15-18] {label}: cost={res.cost_iterations:.2f} "
                      f"conv={res.converged}")
    _write_curves(os.path.join(OUT_DIR, "fig15_18.csv"),
                  ["config", "iterations", "global_residual"], rows)


def main(quick: bool = False):
    fig_1_2()
    fig_3_4()
    fig_global(ks=(2, 8) if quick else (2, 8, 32))


if __name__ == "__main__":
    main()
