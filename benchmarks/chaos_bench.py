"""Recovery-cost bench: what a disruption costs in §2.3 edge pushes.

Each scenario runs one *disturbed* solve under a deterministic
:class:`repro.chaos.ChaosPlan` next to an undisturbed twin and reports
the recovery overhead — the extra edge pushes the disruption (and its
recovery: restore + rescale, takeover, rebalancing) charged on top of
the clean solve — plus the |Δx|₁ agreement of the two solutions (the
chaos harness's correctness oracle).

Scenarios (DESIGN.md §8 taxonomy):

* ``kill_restore``          — session killed mid-solve, recovered from
                              the newest valid checkpoint (frontier
                              backend: the pure crash/restore cost)
* ``kill_restore_rescale``  — engine session killed, restored, and the
                              pid axis shrunk to the surviving width
                              (needs ≥ 2 devices; standalone runs fake
                              8 host devices)
* ``straggler``             — simulator PID slowed 4× under the dynamic
                              policy (the paper's §2.5.2 story under
                              degradation)
* ``straggler_static``      — same disruption, controller OFF: the
                              overhead the dynamic partition saves
* ``rescale``               — simulator elastic shrink mid-solve
* ``engine_rescale``        — engine pid axis shrunk then regrown
                              mid-solve (needs ≥ 4 devices)

  PYTHONPATH=src python -m benchmarks.chaos_bench            # full
  PYTHONPATH=src python -m benchmarks.chaos_bench --smoke    # tiny CI

Emits ``BENCH_chaos.json`` (schema-guarded by ``python -m
benchmarks.run --smoke`` and folded into the consolidated trajectory).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

# a standalone run fakes 8 host devices so the engine scenarios are
# measurable on CPU; when jax was already initialized by a caller
# (benchmarks.run --smoke) the real device count rules and
# device-starved scenarios emit "skipped" rows instead
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()


def _row(scenario: str, method: str, n: int, k: int, n_edges: int,
         undisturbed_ops: int, disturbed_ops: int, x_err: float,
         converged: bool) -> dict:
    return {
        "scenario": scenario,
        "method": method,
        "n": n,
        "k": k,
        "n_edges": int(n_edges),
        "undisturbed_ops": int(undisturbed_ops),
        "disturbed_ops": int(disturbed_ops),
        "overhead_ops": int(disturbed_ops - undisturbed_ops),
        "overhead_frac": round(
            (disturbed_ops - undisturbed_ops) / max(undisturbed_ops, 1), 4),
        "x_err_l1": float(x_err),
        "converged": bool(converged),
    }


def kill_restore_cell(n: int, method: str, k: int = 1,
                      rescale_on_kill: bool = False) -> dict:
    import repro
    from repro.chaos import ChaosPlan, ChaosRunner
    from repro.core import webgraph_like

    g = webgraph_like(n, seed=1)
    problem = repro.Problem.pagerank(g)
    options = repro.SolverOptions(k=k if k > 1 else None)
    plan = ChaosPlan(seed=0).kill(pid=max(k - 1, 0), round=4)
    with tempfile.TemporaryDirectory() as ckpt:
        runner = ChaosRunner(problem, method, plan, ckpt_dir=ckpt,
                             options=options, checkpoint_every=2,
                             rescale_on_kill=rescale_on_kill)
        m = runner.measure()
    scenario = ("kill_restore_rescale" if rescale_on_kill
                else "kill_restore")
    return _row(scenario, method, n, k, problem.n_edges,
                m["undisturbed_ops"], m["disturbed_ops"], m["x_err_l1"],
                m["converged"])


def sim_cell(scenario: str, n: int, k: int, dynamic: bool = True) -> dict:
    import numpy as np

    from repro.chaos import ChaosPlan
    from repro.core import pagerank_system, webgraph_like
    from repro.core.simulator import DistributedSimulator, SimulatorConfig

    g = webgraph_like(n, seed=1)
    p, b = pagerank_system(g)
    mk = lambda: SimulatorConfig(k=k, target_error=1.0 / n, eps=0.15,
                                 mode="batch", dynamic=dynamic,
                                 record_every=50)
    base = DistributedSimulator(p, b, mk()).run()
    if scenario.startswith("straggler"):
        plan = ChaosPlan(seed=0).straggler(pid=1, slowdown=4.0, round=5)
    else:
        plan = ChaosPlan(seed=0).rescale(max(1, k // 2), round=10)
    res = DistributedSimulator(p, b, mk()).run(chaos=plan)
    x_err = float(np.abs(res.h - base.h).sum())
    return _row(scenario, "simulator", n, k, g.n_edges, base.n_edge_ops,
                res.n_edge_ops, x_err, base.converged and res.converged)


def engine_rescale_cell(n: int, k: int) -> dict:
    import numpy as np

    import repro
    from repro.chaos import ChaosPlan, SessionInjector
    from repro.core import webgraph_like

    g = webgraph_like(n, seed=1)
    problem = repro.Problem.pagerank(g)
    options = repro.SolverOptions(k=k, policy="hysteresis")
    ref = repro.SolverSession(problem, method="engine:chunk",
                              options=options).solve()
    plan = (ChaosPlan(seed=0)
            .rescale(max(1, k // 2), round=3)
            .rescale(k, round=6))
    session = repro.SolverSession(problem, method="engine:chunk",
                                  options=options)
    rep = session.solve(chaos=SessionInjector(plan))
    x_err = float(np.abs(rep.x - ref.x).sum())
    return _row("engine_rescale", "engine:chunk", n, k, problem.n_edges,
                ref.n_ops, rep.n_ops, x_err,
                ref.converged and rep.converged)


def main(smoke: bool = False, out_path: str = "BENCH_chaos.json") -> dict:
    import jax

    n_dev = len(jax.devices())
    n_sess = 2**10 if smoke else 2**12
    n_sim = 2**10 if smoke else 2**11
    k_sim = 4 if smoke else 8
    cells = [
        ("kill_restore", lambda: kill_restore_cell(
            n_sess, "frontier:segment_sum")),
        ("straggler", lambda: sim_cell("straggler", n_sim, k_sim)),
        ("rescale", lambda: sim_cell("rescale", n_sim, k_sim)),
    ]
    if not smoke:
        cells.append(("straggler_static", lambda: sim_cell(
            "straggler_static", n_sim, k_sim, dynamic=False)))
    # engine scenarios need physical devices for the pid axis
    k_eng = 2 if smoke else 4
    if n_dev >= k_eng:
        cells.append(("kill_restore_rescale", lambda: kill_restore_cell(
            n_sess, "engine:chunk", k=k_eng, rescale_on_kill=True)))
        cells.append(("engine_rescale",
                      lambda: engine_rescale_cell(n_sess, k_eng)))
    rows = []
    for name, fn in cells:
        try:
            row = fn()
        except Exception as e:
            row = {"scenario": name, "skipped": str(e)}
        rows.append(row)
        if "skipped" in row:
            print(f"  {name}: skipped: {row['skipped']}")
        else:
            print(f"  {name:22s} {row['method']:20s} k={row['k']} "
                  f"overhead={row['overhead_ops']:>8d} ops "
                  f"({row['overhead_frac']:+.1%}), "
                  f"|dx|1={row['x_err_l1']:.2e}")
    from benchmarks._meta import std_meta

    payload = {
        "meta": std_meta(
            "chaos_recovery_overhead",
            graph="webgraph_like",
            n_devices=n_dev,
        ),
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[chaos bench] wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    _payload = main(smoke="--smoke" in sys.argv)
    _real = [r for r in _payload["rows"] if "skipped" not in r]
    # per-cell exceptions become "skipped" rows on purpose (device-
    # starved hosts), but a run that measured NOTHING — or measured a
    # scenario that failed to converge after recovery — must fail loudly
    sys.exit(0 if _real and all(r["converged"] for r in _real) else 1)
