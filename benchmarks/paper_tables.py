"""Paper Tables 1/2/3: computation cost vs K under three node orderings.

Protocol (§3.1): synthetic power-law graph (α = 1.5), N = 1000,
target error 1/N, PageRank system (damping 0.85, ε = 0.15);
K ∈ {1, 2, 4, 8, 16} × {Uniform, CB} × {static, dynamic}; node order
random (Table 1), by out-degree (Table 2), by in-degree (Table 3).

The graph instance is regenerated (the paper's exact instance is not
published); absolute costs differ from the paper's single draw, the
qualitative orderings (dynamic ≥ static robustness, skewed orders hurting
static partitions) are asserted in benchmarks/run.py and tests.
"""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    pagerank_system,
    power_law_graph,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "paper")

KS = (1, 2, 4, 8, 16)


def run_table(order: str, n: int = 1000, seed: int = 0,
              mode: str = "sequential", ks=KS, verbose=True
              ) -> Dict[Tuple, float]:
    g = power_law_graph(n, alpha=1.5, seed=seed)
    if order == "out_degree":
        g = g.reorder(np.argsort(-g.out_degree(), kind="stable"))
    elif order == "in_degree":
        g = g.reorder(np.argsort(-g.in_degree(), kind="stable"))
    p, b = pagerank_system(g, damping=0.85)
    out = {}
    for k in ks:
        for part in ("uniform", "cb"):
            for dyn in (False, True):
                cfg = SimulatorConfig(
                    k=k, target_error=1.0 / n, eps=0.15, partition=part,
                    dynamic=dyn, mode=mode, record_every=100,
                )
                t0 = time.time()
                res = DistributedSimulator(p, b, cfg).run()
                out[(k, part, dyn)] = res.cost_iterations
                if verbose:
                    print(f"  order={order} K={k} {part} "
                          f"{'dyn' if dyn else 'sta'}: "
                          f"{res.cost_iterations:.2f} "
                          f"({time.time()-t0:.1f}s, conv={res.converged})")
    return out


def write_csv(table: Dict, path: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["K", "unif_static", "unif_dynamic", "cb_static",
                    "cb_dynamic"])
        for k in sorted({key[0] for key in table}):
            w.writerow([
                k,
                f"{table[(k, 'uniform', False)]:.3f}",
                f"{table[(k, 'uniform', True)]:.3f}",
                f"{table[(k, 'cb', False)]:.3f}",
                f"{table[(k, 'cb', True)]:.3f}",
            ])


def main(quick: bool = False):
    orders = [("random", "table1"), ("out_degree", "table2"),
              ("in_degree", "table3")]
    tables = {}
    for order, name in orders:
        print(f"[{name}] node order: {order}")
        t = run_table(order, ks=(1, 2, 4, 8, 16) if not quick else (1, 4))
        write_csv(t, os.path.join(os.path.abspath(OUT_DIR), f"{name}.csv"))
        tables[name] = t
    return tables


if __name__ == "__main__":
    main()
