"""Distributed-engine benchmark: segment_sum vs BSR diffusion backends.

Times the jitted chunk of :class:`repro.core.distributed.DistributedEngine`
on a host-ordered web graph (the block-compressible structure the BSR
tiling exploits) and checks both backends converge to the same residual.
Emits ``BENCH_engine.json`` so the engine's perf trajectory has a seed
point next to the kernel sweep's.

Multi-device rows run in a subprocess with fake host devices (the XLA
device count must be set before JAX initialises); ``--child`` is that
subprocess entry and prints one JSON row.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np


def run_config(n: int, k: int, backend: str, buckets_per_dev: int,
               headroom: int, n_chunks: int = 8, target_error: float = 1e-6,
               seed: int = 1) -> dict:
    """Build the engine, time ``n_chunks`` jitted chunks, report a row."""
    import jax

    from repro.balance import BucketMoveExecutor
    from repro.core import host_block_graph, pagerank_system
    from repro.core.distributed import (
        DistributedEngine,
        EngineConfig,
        build_engine_arrays,
    )

    g = host_block_graph(n, host_size=128, links_per_node=8.0,
                         intra_frac=0.92, span_hosts=2, seed=seed)
    p, b = pagerank_system(g)
    cfg = EngineConfig(k=k, target_error=target_error, eps=0.15,
                       buckets_per_dev=buckets_per_dev, headroom=headroom,
                       diffusion_backend=backend)
    t_build0 = time.perf_counter()
    arrs = build_engine_arrays(p, b, cfg)
    build_s = time.perf_counter() - t_build0
    eng = DistributedEngine(arrs, cfg)
    ex = BucketMoveExecutor(eng, eng.init_state())

    # compile + warm one chunk, then time the steady-state chunk loop
    ex.state, stats = eng._chunk(ex.state, *ex.chunk_operands())
    jax.block_until_ready(stats["residual"])
    rounds_warm = int(np.asarray(ex.state.rounds))  # untimed rounds so far
    t0 = time.perf_counter()
    for _ in range(n_chunks):
        ex.state, stats = eng._chunk(ex.state, *ex.chunk_operands())
    jax.block_until_ready(stats["residual"])
    dt = time.perf_counter() - t0
    rounds = int(np.asarray(ex.state.rounds))
    rounds_timed = rounds - rounds_warm
    resid = float(np.asarray(stats["residual"])) + float(
        np.asarray(stats["s"]).sum())
    row = {
        "n": n, "k": k, "backend": backend,
        "buckets_per_dev": buckets_per_dev, "headroom": headroom,
        "n_edges": g.n_edges,
        "bucket_size": arrs.bucket_size,
        "chunk_ms": round(dt / n_chunks * 1e3, 2),
        "rounds": rounds,
        "us_per_round": round(dt / max(rounds_timed, 1) * 1e6, 1),
        "residual_after": resid,
        "build_s": round(build_s, 2),
    }
    if arrs.tiles is not None:
        row["n_tiles"] = int(
            (np.abs(arrs.tiles).sum(axis=(2, 3)) > 0).sum())
        row["tile_shape"] = list(arrs.tiles.shape)
    return row


def _spawn_child(n, k, backend, buckets_per_dev, headroom) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={k}")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.engine_bench", "--child",
           "--n", str(n), "--k", str(k), "--backend", backend,
           "--buckets-per-dev", str(buckets_per_dev),
           "--headroom", str(headroom)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=3600,
                       env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    if r.returncode != 0:
        raise RuntimeError(f"engine bench child failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(smoke: bool = False, out_path: str = "BENCH_engine.json"):
    import jax

    rows = []
    from benchmarks._meta import std_meta

    meta = std_meta(
        "engine_chunk_rounds",
        seed=1,
        graph="host_block_graph(host_size=128, links_per_node=8, "
              "intra_frac=0.92, span_hosts=2)",
        note=("chunk_ms times the steady-state jitted chunk "
              "(chunk_rounds exchange cycles incl. psum_scatter); "
              "k>1 rows run on fake host devices in a subprocess. "
              "On CPU the bsr backend runs the einsum tile path; the "
              "Pallas gather kernel takes over on TPU."),
        smoke=smoke,
    )
    if smoke:
        grid = [(2**12, 1, 36, 4)]
    else:
        grid = [(2**16, 1, 520, 8), (2**16, 8, 72, 8), (2**17, 8, 136, 8)]
    for n, k, bpd, hr in grid:
        for backend in ("segment_sum", "bsr"):
            if k == 1:
                row = run_config(n, k, backend, bpd, hr,
                                 n_chunks=2 if smoke else 8)
            else:
                row = _spawn_child(n, k, backend, bpd, hr)
            rows.append(row)
            print(f"[engine] N={n} K={k} {backend}: "
                  f"chunk={row['chunk_ms']}ms rounds={row['rounds']} "
                  f"resid={row['residual_after']:.3e}")
    # backend pairs must agree on the residual they reach
    for i in range(0, len(rows), 2):
        a, b = rows[i], rows[i + 1]
        drift = abs(a["residual_after"] - b["residual_after"])
        scale = max(abs(a["residual_after"]), 1e-12)
        agree = drift <= 1e-5 + 1e-2 * scale
        rows[i + 1]["residual_agrees_with_segment_sum"] = bool(agree)
        if not agree:
            print(f"[engine] WARNING residual drift {drift:.3e} "
                  f"between backends at row {i}")
    payload = {"meta": meta, "rows": rows}
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"[engine] wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n", type=int, default=2**16)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--backend", default="segment_sum")
    ap.add_argument("--buckets-per-dev", type=int, default=72)
    ap.add_argument("--headroom", type=int, default=8)
    args = ap.parse_args()
    if args.child:
        row = run_config(args.n, args.k, args.backend,
                         args.buckets_per_dev, args.headroom)
        print(json.dumps(row))
    else:
        main(smoke=args.smoke)
