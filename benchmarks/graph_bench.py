"""Delta-vs-cold sweep: what an evolving graph costs with GraphStore.

For each (N, churn fraction, method) cell a converged session absorbs a
link-rotation delta through ``SolverSession.update_graph`` (GraphStore
patches its views in place, ``F' = F + (P'−P)·H`` re-seeds the fluid)
and the warm re-solve's edge pushes are compared against a cold solve
of the *same patched problem*.  Also times the incremental view patch
against a from-scratch store rebuild + re-materialization of the same
views — the structural half of the win.  Emits ``BENCH_graph.json``
(schema-guarded by ``python -m benchmarks.run --smoke`` and folded into
the consolidated ``BENCH.json`` trajectory).

  PYTHONPATH=src python -m benchmarks.graph_bench           # N=2^12, 2^13
  PYTHONPATH=src python -m benchmarks.graph_bench --smoke   # tiny CI run
"""
from __future__ import annotations

import json
import sys
import time


def run_cell(n: int, churn_frac: float, method: str, seed: int = 7) -> dict:
    import repro
    from repro.core import webgraph_like
    from repro.graph import GraphStore, rotation_churn

    g = webgraph_like(n, seed=1)
    problem = repro.Problem.pagerank(g)
    session = repro.SolverSession(problem, method=method)
    cold_pre = session.solve()
    rank = cold_pre.x

    store = session.problem.graph
    n_rot = max(1, int(churn_frac * problem.n_edges) // 2)
    delta = rotation_churn(store, n_rot, seed=seed, rank=rank,
                           exclude_top=0.2)

    # structural cost: incremental patch vs from-scratch rebuild of the
    # same view set, measured on a twin store so the timing isolates
    # apply_delta (the session's own update_graph also rebuilds its
    # driver, which is re-upload/jit cost, not view maintenance)
    def materialize(s: GraphStore) -> GraphStore:
        for key in store.materialized_views():
            if key[0] == "bsr":
                s.bsr(key[1])
            elif key[0] == "bucket":
                s.bucketed(key[1])
            elif key[0] == "engine":
                s.engine_layout(key[1], key[2], key[3], tiled=key[4],
                                dtype=key[5])
        return s

    twin = materialize(GraphStore.from_csr(store.csr()))
    t0 = time.perf_counter()
    twin.apply_delta(delta)
    patch_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    materialize(GraphStore.from_csr(twin.csr()))
    rebuild_s = time.perf_counter() - t0

    resid0 = session.update_graph(delta)
    warm = session.solve()
    cold = repro.SolverSession(session.problem, method=method).solve()
    return {
        "n": n,
        "method": method,
        "n_edges": int(problem.n_edges),
        "churn_frac": churn_frac,
        "changed_edges": int(delta.n_changes),
        "f0_resid": float(resid0),
        "warm_ops": int(warm.n_ops),
        "cold_ops": int(cold.n_ops),
        "ops_ratio": round(cold.n_ops / max(warm.n_ops, 1), 2),
        "patch_s": round(patch_s, 4),
        "rebuild_s": round(rebuild_s, 4),
        "patch_speedup": round(rebuild_s / max(patch_s, 1e-9), 2),
        "converged": bool(warm.converged and cold.converged),
    }


def main(smoke: bool = False, out_path: str = "BENCH_graph.json") -> dict:
    import jax

    ns = (2**10,) if smoke else (2**12, 2**13)
    churns = (0.01,) if smoke else (0.002, 0.01, 0.05)
    methods = (("frontier:segment_sum",) if smoke
               else ("frontier:segment_sum", "engine:bsr"))
    rows = []
    for n in ns:
        for churn in churns:
            for method in methods:
                try:
                    row = run_cell(n, churn, method)
                except Exception as e:  # device constraints etc.
                    row = {"n": n, "method": method, "churn_frac": churn,
                           "skipped": str(e)}
                rows.append(row)
                if "skipped" in row:
                    print(f"  N=2^{n.bit_length()-1} churn={churn} "
                          f"{method}: skipped: {row['skipped']}")
                else:
                    print(f"  N=2^{n.bit_length()-1} churn={churn:5.3f} "
                          f"{method:22s} warm={row['warm_ops']:>9d} "
                          f"cold={row['cold_ops']:>9d} "
                          f"({row['ops_ratio']:4.1f}x fewer pushes, "
                          f"patch {row['patch_speedup']:5.1f}x faster "
                          f"than rebuild)")
    from benchmarks._meta import std_meta

    payload = {
        "meta": std_meta(
            "graph_delta_vs_cold",
            seed=7,
            graph="webgraph_like + rotation_churn(exclude_top=0.2)",
        ),
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[graph bench] wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
