"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Three terms per (arch × shape × mesh), all in seconds-per-step on TPU v5e:

    compute    = flops_per_device / peak_flops            (197 TFLOP/s bf16)
    memory     = bytes_per_device / hbm_bw                (819 GB/s)
    collective = moved_ici / ici_bw + moved_dcn / dcn_bw

Link model: every v5e chip has 4 ICI links × ~50 GB/s => 200 GB/s aggregate
per chip intra-pod; the pod axis crosses DCN at ~6.25 GB/s per chip.

Sources per family:

* **GNN / recsys / solver** — flops & bytes straight from
  ``compiled.cost_analysis()`` (per-device, post-SPMD; these programs have
  no data-dependent loops so the counters are exact).
* **LM** — XLA:CPU's cost analysis counts ``while`` (scan) bodies ONCE
  (probe in EXPERIMENTS.md §Dry-run), so scanned-layer models are
  undercounted ~L·nm×.  LM terms therefore use the standard analytic
  accounting (PaLM-style MFU math): 6·N_active·T + attention for training
  (×4/3 for remat recompute), plus an explicit per-component byte model
  (weights/optimizer/activations/scores/CE-logits/KV-cache).  The raw HLO
  numbers are kept as reference columns.
* **collectives** (all families) — the HLO inventory with while-trip
  correction applied at parse time (launch/dryrun.parse_collectives).

``roofline_fraction`` = irreducible step time / modelled bottleneck time,
where irreducible = max(useful_flops/peak, irreducible_bytes/hbm_bw) — the
score of how close the lowered program is to the best achievable step.
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 4 * 50e9  # 4 links x 50 GB/s aggregate per chip
DCN_BW = 6.25e9  # per chip across the pod axis

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MESHES = {
    "pod16x16": dict(n_dev=256, dp=16, tp=16),
    "pod2x16x16": dict(n_dev=512, dp=32, tp=16),
}

# byte-model coefficients (documented in EXPERIMENTS.md §Roofline)
C_ACT = 20.0  # residual-stream tensor r/w per layer (fwd+remat+bwd)
C_SCORE = 6.0  # attention score matrix passes (f32)
C_CE = 4.0  # CE logits chunk materialisations (write+read, fwd+bwd)
C_MOE = 6.0  # MoE dispatch buffer passes


def _lm_flops(cfg, meta, kind):
    n_act = cfg.n_active_params
    l, hq, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    s, t = meta.get("seq", 0), meta.get("tokens", 0)
    if kind == "train":
        useful = 6.0 * n_act * t + 6.0 * l * t * s * hq * dh
        return useful, useful * 4.0 / 3.0  # remat recompute
    if kind == "prefill":
        useful = 2.0 * n_act * t + 2.0 * l * t * s * hq * dh
        return useful, useful
    useful = 2.0 * n_act * t + 4.0 * l * t * s * cfg.n_kv_heads * dh
    return useful, useful


def _lm_bytes(cfg, meta, kind, mesh):
    """(irreducible_bytes, modelled_bytes) per device."""
    tp, dp, n_dev = mesh["tp"], mesh["dp"], mesh["n_dev"]
    l, d, v = cfg.n_layers, cfg.d_model, cfg.vocab
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s, t, b = meta.get("seq", 0), meta.get("tokens", 0), meta.get("batch", 1)
    p_bytes = cfg.n_params * 2.0
    w_shard = p_bytes / tp  # TP-gathered weight reads per pass
    if kind == "train":
        t_dev = t / dp
        w = 3.0 * w_shard
        opt = 8.0 * 4.0 * cfg.n_params / n_dev
        act = C_ACT * l * t_dev * d * 2.0
        score = C_SCORE * l * t_dev * s * (hq / tp) * 4.0
        ce = C_CE * t_dev * math.ceil(v / tp) * 4.0
        moe = 0.0
        if cfg.moe is not None:
            moe = (C_MOE * l * t_dev * cfg.moe.top_k
                   * cfg.moe.capacity_factor * d * 2.0)
        total = w + opt + act + score + ce + moe
        irreducible = 2.0 * w_shard + opt + 2.0 * t_dev * d * 2.0 * l \
            + t_dev * math.ceil(v / tp) * 4.0
        return irreducible, total
    kv_bytes = meta.get("kv_bytes", 2)
    cache_total = l * b * s * hkv * dh * 2.0 * kv_bytes
    if kind == "prefill":
        t_dev = t / dp
        cache_dev = cache_total / (dp * tp)
        w = w_shard
        act = 8.0 * l * t_dev * d * 2.0
        score = 2.0 * l * t_dev * s * (hq / tp) * 4.0
        total = w + act + score + cache_dev
        irreducible = w + cache_dev + t_dev * d * 2.0 * l
        return irreducible, total
    # decode: one token per sequence against an S cache
    shards = dp * tp if b >= dp else tp  # long_500k: batch unshardable
    cache_dev = cache_total / shards
    b_dev = max(b / dp, 1) if b >= dp else b
    w = w_shard
    logits = b_dev * math.ceil(v / tp) * 4.0
    total = w + 2.0 * cache_dev + logits + b_dev * l * d * 2.0 * 10.0
    irreducible = w + cache_dev + logits
    return irreducible, total


def model_terms(rec: Dict) -> Optional[Dict]:
    """Analytic (useful_flops, modelled_flops, irreducible_b, modelled_b)."""
    from repro.configs import get_arch

    arch = rec["arch"]
    if arch == "diteration-solver":
        useful = 2.0 * rec["meta"]["edges"] / rec["n_devices"] * 8
        return {"useful_flops_dev": useful, "flops_dev": None,
                "irreducible_bytes_dev": None, "bytes_dev": None}
    spec = get_arch(arch)
    cfg = spec.model_cfg
    mesh = MESHES[rec["mesh"]]
    if spec.family == "lm":
        useful, modelled = _lm_flops(cfg, rec["meta"], rec["kind"])
        irr_b, mod_b = _lm_bytes(cfg, rec["meta"], rec["kind"], mesh)
        return {
            "useful_flops_dev": useful / mesh["n_dev"],
            "flops_dev": modelled / mesh["n_dev"],
            "irreducible_bytes_dev": irr_b,
            "bytes_dev": mod_b,
        }
    # GNN / recsys: HLO counters are exact; useful flops analytic
    if spec.family == "gnn":
        useful = _gnn_model_flops(cfg, rec["meta"]) / mesh["n_dev"]
    else:
        useful = _fm_model_flops(cfg, rec["meta"],
                                 rec["kind"]) / mesh["n_dev"]
    return {"useful_flops_dev": useful, "flops_dev": None,
            "irreducible_bytes_dev": None, "bytes_dev": None}


def _gnn_model_flops(arch_cfg, meta: Dict) -> float:
    n, e = meta["n_nodes"], meta["n_edges"]
    d = arch_cfg.d_hidden
    a = arch_cfg.arch
    if a == "gin":
        fwd = arch_cfg.n_layers * n * 4 * d * d + n * 2 * d * d
    elif a == "meshgraphnet":
        per = (e * 2 * (3 * d) * d + e * 2 * d * d
               + n * 2 * (2 * d) * d + n * 2 * d * d)
        fwd = arch_cfg.n_layers * per + (n + e) * 4 * d * d
    elif a == "egnn":
        per = (e * 2 * (2 * d + 1) * d + e * 4 * d * d
               + n * 2 * (2 * d) * d + n * 2 * d * d)
        fwd = arch_cfg.n_layers * per
    elif a == "dimenet":
        tpe = meta.get("n_triplets", 8 * e)
        nb = arch_cfg.n_bilinear
        per = (tpe * 2 * nb * d * d + e * 2 * (2 * d) * d
               + e * 4 * d * d)
        fwd = arch_cfg.n_layers * per + e * 2 * (3 * d) * d
    else:
        fwd = 0.0
    return 3.0 * fwd


def _fm_model_flops(arch_cfg, meta: Dict, kind: str) -> float:
    b = meta.get("batch", 1)
    f, d = arch_cfg.n_fields, arch_cfg.embed_dim
    fwd = b * f * d * 4.0
    if kind == "retrieval":
        fwd = meta.get("n_candidates", 1) * d * 2.0
    return (3.0 if kind == "train" else 1.0) * fwd


def analyse(path: str) -> Dict:
    rec = json.load(open(path))
    if "skipped" in rec:
        return rec
    mesh = MESHES[rec["mesh"]]
    hlo_flops = rec["cost"]["flops_per_device"] or 0.0
    hlo_bytes = rec["cost"]["bytes_per_device"] or 0.0
    mt = model_terms(rec) or {}
    flops_dev = mt.get("flops_dev") or hlo_flops
    bytes_dev = mt.get("bytes_dev") or hlo_bytes
    useful = mt.get("useful_flops_dev") or hlo_flops
    irr_b = mt.get("irreducible_bytes_dev") or hlo_bytes

    ici = rec["collectives"].get(
        "moved_bytes_ici", rec["collectives"].get("moved_bytes_total", 0.0))
    dcn = rec["collectives"].get("moved_bytes_dcn", 0.0)
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = ici / ICI_BW + dcn / DCN_BW
    bound = max(t_comp, t_mem, t_coll)
    dominant = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    t_irreducible = max(useful / PEAK_FLOPS, irr_b / HBM_BW)
    frac = (t_irreducible / bound) if bound else None
    return {
        "arch": rec["arch"],
        "cell": rec["cell"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "useful_ratio": (useful / flops_dev) if flops_dev else None,
        "roofline_fraction": frac,
        "hlo_flops_dev": hlo_flops,
        "model_flops_dev": flops_dev,
        "useful_flops_dev": useful,
        "bytes_dev": bytes_dev,
        "collective_gib_dev": (ici + dcn) / 2**30,
        "mem_args_gib": (rec["memory"].get("argument_bytes") or 0) / 2**30,
        "mem_temp_gib": (rec["memory"].get("temp_bytes") or 0) / 2**30,
    }


def build_table(results_dir: str = None, mesh_filter: str = None):
    results_dir = results_dir or os.path.abspath(RESULTS)
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = analyse(f)
        if "skipped" in r:
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rows.append(r)
    return rows


def to_markdown(rows) -> str:
    out = ["| arch | cell | mesh | compute s | memory s | collective s | "
           "dominant | useful/modelled | roofline frac |\n",
           "|---|---|---|---|---|---|---|---|---|\n"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        u = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "n/a"
        fr = (f"{r['roofline_fraction']:.3f}"
              if r["roofline_fraction"] is not None else "n/a")
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {u} | {fr} |\n")
    return "".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--csv", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    rows = build_table(args.dir, args.mesh)
    print(f"{'arch':<22}{'cell':<15}{'mesh':<11}{'comp_s':>9}{'mem_s':>9}"
          f"{'coll_s':>9} {'dominant':<11}{'useful':>7}{'frac':>7}")
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        u = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "n/a"
        fr = (f"{r['roofline_fraction']:.3f}"
              if r["roofline_fraction"] is not None else "n/a")
        print(f"{r['arch']:<22}{r['cell']:<15}{r['mesh']:<11}"
              f"{r['t_compute_s']:>9.2e}{r['t_memory_s']:>9.2e}"
              f"{r['t_collective_s']:>9.2e} {r['dominant']:<11}"
              f"{u:>7}{fr:>7}")
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(to_markdown(rows))
        print(f"wrote {args.md}")


if __name__ == "__main__":
    main()
