"""Roofline analysis of the BSR diffusion kernels (rebuilt for the PR-6
pipelined kernels; the old version predated the BSR kernel and read
``results/dryrun/`` artifacts that no longer exist).

The model lives in :mod:`repro.kernels.tune.model` — bytes are what the
kernels actually move (**active** tiles × tile bytes at the swept
frontier density, plus the fluid streams), flops are the MXU work of the
active tiles only, because the scalar-prefetched occupancy skip makes
inactive tiles free.  Per measured row this module derives:

* ``roofline_fraction`` — ideal-time / measured-time against the
  platform's nominal envelope (interpret/oracle rows land far below 1.0
  by design; the field tracks the *trajectory*, hardware runs move it),
* ``dma_compute_ratio`` — tile-stream DMA time over MXU time: >1 means
  the kernel is DMA-bound and ``buffer_depth`` can only hide (never
  remove) the gap,
* ``arithmetic_intensity`` and the binding wall (memory vs compute).

``annotate_payload`` merges these into BENCH_kernels.json rows at emit
time; ``build_table`` recomputes them from a committed artifact.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.kernels.tune.model import (  # noqa: E402
    PLATFORM_SPECS,
    dma_compute_ratio,
    frontier_round_cost,
    ideal_time_s,
    roofline_fraction,
)

BENCH_PATH = "BENCH_kernels.json"

# the timed columns of a frontier-sweep row, and which tile population
# each one touches (the skip path only moves/multiplies active tiles)
_MEASURED_COLS = (
    ("pallas_skip_us", "n_blocks_active"),
    ("bsr_full_us", "n_blocks"),
)


def analyse_row(row: Dict, bs: int, platform: str) -> Optional[Dict]:
    """Roofline terms for one sweep row; None for skipped rows."""
    if "skipped" in row or "n" not in row:
        return None
    spec = PLATFORM_SPECS.get(platform, PLATFORM_SPECS["cpu"])
    n, c = int(row["n"]), int(row["c"])
    n_row_blocks = -(-n // bs)
    out: Dict = {}
    for col, pop in _MEASURED_COLS:
        if row.get(col) is None or pop not in row:
            continue
        cost = frontier_round_cost(n_row_blocks, bs, c, int(row[pop]))
        ideal_s, bound = ideal_time_s(cost, spec)
        frac = roofline_fraction(row[col] * 1e-6, ideal_s)
        out[col] = {
            "bytes": cost.total_bytes,
            "flops": cost.flops,
            "arithmetic_intensity": round(cost.arithmetic_intensity, 4),
            "ideal_us": round(ideal_s * 1e6, 3),
            "bound": bound,
            "dma_compute_ratio": round(dma_compute_ratio(cost, spec), 3),
            "roofline_fraction": round(frac, 6),
        }
    return out or None


def annotate_payload(payload: Dict) -> Dict:
    """Merge roofline fields into sweep rows, in place (emit-time hook).

    The headline ``roofline_fraction`` / ``dma_compute_ratio`` of a row
    follow its occupancy-skip measurement (the deployable path); the
    full-path fraction keeps a ``full_`` prefix.
    """
    meta = payload.get("meta", {})
    platform = meta.get("platform", meta.get("backend", "cpu"))
    bs = int(meta.get("bs", 128))
    for row in payload.get("rows", []):
        terms = analyse_row(row, bs, platform)
        if terms is None:
            continue
        row.setdefault("buffer_depth", 1)
        skip = terms.get("pallas_skip_us")
        if skip is not None:
            row["roofline_fraction"] = skip["roofline_fraction"]
            row["dma_compute_ratio"] = skip["dma_compute_ratio"]
            row["arithmetic_intensity"] = skip["arithmetic_intensity"]
        full = terms.get("bsr_full_us")
        if full is not None:
            row["full_roofline_fraction"] = full["roofline_fraction"]
    return payload


def build_table(bench_path: str = BENCH_PATH) -> List[Dict]:
    """Roofline table recomputed from a BENCH_kernels.json artifact."""
    if not os.path.exists(bench_path):
        return []
    with open(bench_path) as fh:
        payload = json.load(fh)
    meta = payload.get("meta", {})
    platform = meta.get("platform", meta.get("backend", "cpu"))
    bs = int(meta.get("bs", 128))
    table: List[Dict] = []
    for row in payload.get("rows", []):
        terms = analyse_row(row, bs, platform)
        if terms is None:
            continue
        skip = terms.get("pallas_skip_us") or terms.get("bsr_full_us")
        if skip is None:
            continue
        table.append({
            "n": row["n"],
            "c": row["c"],
            "density": row["density"],
            "bs": bs,
            "buffer_depth": row.get("buffer_depth", 1),
            "measured_us": row.get("pallas_skip_us",
                                   row.get("bsr_full_us")),
            "ideal_us": skip["ideal_us"],
            "arithmetic_intensity": skip["arithmetic_intensity"],
            "bound": skip["bound"],
            "dma_compute_ratio": skip["dma_compute_ratio"],
            "roofline_fraction": skip["roofline_fraction"],
        })
    return table


def main(argv=None) -> int:
    path = BENCH_PATH
    if argv and argv[0] not in ("-h", "--help"):
        path = argv[0]
    table = build_table(path)
    if not table:
        print(f"no analysable rows in {path} — run "
              "python -m benchmarks.kernel_bench --sweep first")
        return 1
    print("n,c,density,depth,measured_us,ideal_us,ai,bound,"
          "dma_compute_ratio,roofline_fraction")
    for r in table:
        print(f"{r['n']},{r['c']},{r['density']},{r['buffer_depth']},"
              f"{r['measured_us']},{r['ideal_us']},"
              f"{r['arithmetic_intensity']},{r['bound']},"
              f"{r['dma_compute_ratio']},{r['roofline_fraction']}")
    membound = sum(1 for r in table if r["bound"] == "memory")
    print(f"# {len(table)} rows; {membound} memory-bound, "
          f"{len(table) - membound} compute-bound")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
