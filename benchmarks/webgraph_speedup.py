"""Paper Figures 5/6: convergence speed-up factor vs K on the web graph.

uk-2007-05@1000000 is not downloadable offline; the stand-in is
``webgraph_like`` matched to Table 4 (L/N ≈ 12.9, dangling ≈ 4.1%,
power-law degrees with host-locality bias) — DESIGN.md §1 records the
substitution.  N ∈ {1000, 10000[, 100000]}, K ∈ {1..64}, speedup =
cost(K=1)/cost(K), from Uniform (Fig 5) and CB (Fig 6) starts, each
static vs dynamic.
"""
from __future__ import annotations

import csv
import os
import time

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    pagerank_system,
    webgraph_like,
)

OUT_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "results", "paper")
)


def run(ns=(1000, 10000), ks=(1, 2, 4, 8, 16, 32, 64), verbose=True):
    rows = []
    for n in ns:
        g = webgraph_like(n, seed=1)
        p, b = pagerank_system(g)
        base = None
        for k in ks:
            if k > n // 4:
                continue
            for part in ("uniform", "cb"):
                for dyn in (False, True):
                    cfg = SimulatorConfig(
                        k=k, target_error=1.0 / n, eps=0.15,
                        partition=part, dynamic=dyn, mode="batch",
                        record_every=200, max_steps=500_000,
                    )
                    t0 = time.time()
                    res = DistributedSimulator(p, b, cfg).run()
                    cost = res.cost_iterations
                    if k == 1 and part == "uniform" and not dyn:
                        base = cost
                    speedup = base / cost if base else 1.0
                    rows.append([n, k, part, int(dyn), f"{cost:.4f}",
                                 f"{speedup:.3f}"])
                    if verbose:
                        print(f"  N={n} K={k} {part} "
                              f"{'dyn' if dyn else 'sta'}: cost={cost:.2f} "
                              f"speedup={speedup:.2f} "
                              f"({time.time()-t0:.1f}s)")
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "fig5_6.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["N", "K", "partition", "dynamic", "cost", "speedup"])
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    import sys

    full = "--full" in sys.argv
    run(ns=(1000, 10000, 100000) if full else (1000, 10000))
