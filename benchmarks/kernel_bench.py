"""Kernel microbenchmarks.

Two parts:

* :func:`main` — wall time of the XLA reference paths on CPU (the Pallas
  kernels are TPU-target and validated in interpret mode — CPU interpret
  timings are not meaningful) + derived figures (bytes, flops, arithmetic
  intensity) used in the roofline discussion.  Prints
  ``name,us_per_call,derived`` CSV as required.

* :func:`frontier_sweep` — the solver hot path head-to-head: one frontier
  round on a host-ordered web graph via (a) the per-edge
  gather→multiply→``segment_sum`` path that ``solve_frontier_jnp`` and the
  engine historically ran, (b) the full BSR block path, and (c) the BSR
  path restricted to occupied block columns — the work the fused Pallas
  kernel's scalar-prefetched occupancy map does on TPU (``pl.when`` skips
  the MXU work of inactive tiles; off-TPU we measure the equivalent
  compacted block list).  The block oracle is jitted ONCE at module level
  and keyed on static shape only — compacted block lists are padded to
  power-of-two buckets with zero tiles so density cells share traces
  (zero tiles contribute exactly 0.0; numerics are unchanged).  Each cell
  is swept over ``buffer_depth`` and every row carries the model-derived
  ``roofline_fraction`` / ``dma_compute_ratio`` (benchmarks/roofline.py).
  Emits ``BENCH_kernels.json``; interpret-mode *correctness* of the real
  kernel — including cross-depth bit parity — is asserted on the smallest
  cell of every sweep.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._meta import std_meta
from benchmarks.roofline import annotate_payload

from repro.core import host_block_graph, pagerank_system, power_law_graph
from repro.kernels.attention import attention_ref
from repro.kernels.diffusion import (
    BsrMatrix,
    bsr_spmm,
    bsr_spmm_ref,
    frontier_round_bsr,
    frontier_round_bsr_pallas,
    frontier_round_ref,
    prepare_bsr,
)
from repro.kernels.fm import fm_interaction_ref
from repro.kernels.segment import segment_sum_ref


def timeit(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))  # compile + warm (array or pytree)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# --------------------------------------------------------------------------- #
# frontier-round sweep: segment_sum vs BSR block path vs occupancy skip
# --------------------------------------------------------------------------- #
def _edge_round_fn(src, dst, wgt, n, c):
    """The per-edge baseline: full edge list touched every round."""

    @jax.jit
    def round_(f, w, t):
        sel = jnp.abs(f) * w[:, None] > t
        sent = jnp.where(sel, f, jnp.zeros_like(f))
        msg = sent[src] * wgt[:, None]  # [L, C]
        delta = jax.ops.segment_sum(msg, dst, num_segments=n)
        return (f - sent) + delta, jnp.sum(jnp.abs(delta))

    return round_


# ONE module-level jit for every block-oracle timing: the cache keys on
# operand shapes + the static (n_row_blocks, bs) pair, so density cells
# reuse each other's traces instead of re-jitting a fresh closure per cell
# (the historical wall-time sink this bench's meta used to apologise for).
@functools.partial(jax.jit, static_argnames=("n_row_blocks", "bs"))
def _block_round(blocks, block_row, block_col, f, w, t, *, n_row_blocks,
                 bs):
    sel = jnp.abs(f) * w[:, None] > t
    sent = jnp.where(sel, f, jnp.zeros_like(f))
    xt = sent.reshape(-1, bs, f.shape[1])
    delta = bsr_spmm_ref(blocks, block_row, block_col, xt, n_row_blocks)
    f_new = (f - sent) + delta.reshape(f.shape)
    return f_new, jnp.sum(jnp.abs(f_new))


def _block_round_args(m: BsrMatrix):
    """(positional args, static kwargs) for :func:`_block_round`."""
    return ((m.blocks, m.block_row, m.block_col),
            dict(n_row_blocks=m.n_row_blocks, bs=m.bs))


def _compact_bsr(m: BsrMatrix, active_cols: np.ndarray) -> BsrMatrix:
    """Blocks whose block_col holds frontier fluid — the tile set the
    Pallas occupancy map leaves active (inactive tiles contribute nothing
    because their sent fluid is zero).  The compacted list is padded with
    zero tiles to the next power of two so different frontier densities
    land in a handful of shared jit cache shapes."""
    mask = np.isin(np.asarray(m.block_col), active_cols)
    if not mask.any():
        mask[:1] = True  # degenerate: keep one (zero-contribution) block
    blocks = np.asarray(m.blocks)[mask]
    rows = np.asarray(m.block_row)[mask]
    cols = np.asarray(m.block_col)[mask]
    bucket = 1 << (int(blocks.shape[0]) - 1).bit_length()
    pad = bucket - blocks.shape[0]
    if pad:
        blocks = np.concatenate(
            [blocks, np.zeros((pad, m.bs, m.bs), blocks.dtype)])
        # zero tiles accumulate 0.0 into the last row — numerically inert,
        # and keeping block_row sorted preserves the kernel contract
        rows = np.concatenate(
            [rows, np.full(pad, rows[-1], dtype=rows.dtype)])
        cols = np.concatenate(
            [cols, np.zeros(pad, dtype=cols.dtype)])
    return BsrMatrix(blocks, rows, cols, m.n_row_blocks, m.bs)


def _make_frontier(n_pad, n, c, bs, density, rng):
    """Residual vector with ``density`` of the block columns above T=1.

    Hot blocks get |f| = 2 (selected), cold blocks 0.25 (kept) — the
    mid-convergence shape where most fluid sits under the threshold.
    """
    n_blocks = n_pad // bs
    n_hot = max(1, int(round(density * n_blocks)))
    hot = rng.choice(n_blocks, size=n_hot, replace=False)
    f = np.full((n_pad, c), 0.25, dtype=np.float32)
    signs = rng.choice([-1.0, 1.0], size=(n_pad, c))
    for b in hot:
        f[b * bs : (b + 1) * bs] = 2.0
    f *= signs
    f[n:] = 0.0
    return f, np.sort(hot)


def _verify_depths(m, fj, wj, t, f, w, depths):
    """Interpret-mode check on one cell: kernel vs numpy twin, and bit
    parity of the manual-DMA pipeline across buffer depths."""
    fp, _s, _r = frontier_round_bsr(
        m, fj, wj, t, backend="pallas", interpret=True)
    fr, _sr, _rr = frontier_round_ref(
        np.asarray(m.blocks), np.asarray(m.block_row),
        np.asarray(m.block_col), f, w, float(t))
    np.testing.assert_allclose(np.asarray(fp), fr, rtol=2e-4, atol=2e-4)
    for depth in depths:
        if depth == 1:
            continue
        fd, _s2, _r2 = frontier_round_bsr(
            m, fj, wj, t, backend="pallas", interpret=True,
            buffer_depth=depth)
        if not np.array_equal(np.asarray(fd), np.asarray(fp)):
            raise AssertionError(
                f"buffer_depth={depth} interpret output differs bitwise "
                "from depth=1")


def frontier_sweep(
    ns=(2**16, 2**17, 2**18, 2**19, 2**20, 2**21),
    cs=(1, 8, 64),
    densities=(1.0, 0.25, 0.05),
    bs=128,
    depths=(1, 2, 4),
    iters=3,
    seed=0,
    out_path="BENCH_kernels.json",
    max_cell_floats=3.5e8,  # skip cells whose edge operands exceed this
    max_tile_bytes=14e9,  # skip graphs whose tile pool exceeds this
    verify_interpret=True,
):
    """Sweep N × C × frontier density × buffer depth; write
    ``BENCH_kernels.json`` (roofline-annotated rows)."""
    rng = np.random.default_rng(seed)
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    meta = std_meta(
        "kernel_frontier_sweep",
        seed=seed,
        bs=bs,
        iters=iters,
        depths=list(depths),
        timing_path="pallas" if on_tpu else "oracle",
        graph="host_block_graph(host_size=bs, links_per_node=8, "
              "intra_frac=0.92, span_hosts=2)",
        note=(
            "pallas_skip_us is the occupancy-restricted BSR path: on TPU "
            "the fused kernel skips inactive tiles in-kernel via the "
            "scalar-prefetched col_active map; off-TPU the same tile "
            "subset runs through the module-level jitted jnp block oracle "
            "(cache keyed on shape; compacted lists pow2-padded).  Off-TPU "
            "the oracle has no buffer_depth, so depth rows share the "
            "oracle timing; on TPU each depth times the real pipeline.  "
            "Correctness incl. cross-depth bit parity is asserted in "
            "interpret mode on the smallest cell."
        ),
    )
    verified = False
    for n in ns:
        g = host_block_graph(n, host_size=bs, links_per_node=8.0,
                             intra_frac=0.92, span_hosts=2, seed=1)
        p, _b = pagerank_system(g)
        m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=bs)
        tile_bytes = m.n_blocks * bs * bs * 4
        n_pad = m.n_row_blocks * bs
        if tile_bytes > max_tile_bytes:
            rows.append({"n": n, "skipped": "tile pool exceeds "
                         f"{max_tile_bytes:.0e} bytes ({tile_bytes:.2e})"})
            continue
        src, dst, wgt = p.edge_list()
        srcj = jnp.asarray(src, jnp.int32)
        dstj = jnp.asarray(dst, jnp.int32)
        wgtj = jnp.asarray(wgt, jnp.float32)
        w = np.zeros(n_pad, np.float32)
        w[: p.n] = 1.0
        wj = jnp.asarray(w)
        t = jnp.float32(1.0)
        full_args, full_stat = _block_round_args(m)
        for c in cs:
            if g.n_edges * c > max_cell_floats:
                rows.append({"n": n, "c": c, "skipped":
                             f"edge operands exceed {max_cell_floats:.0e} "
                             "floats"})
                continue
            edge_round = _edge_round_fn(srcj, dstj, wgtj, n_pad, c)
            # big cells: one timed call is enough — the paths differ by
            # orders of magnitude and the warm call already primed caches
            it = 1 if g.n_edges * c > 8e7 else iters
            for d in densities:
                f, hot = _make_frontier(n_pad, p.n, c, bs, d, rng)
                fj = jnp.asarray(f)
                edge_us = timeit(edge_round, fj, wj, t, iters=it)
                block_us = timeit(
                    lambda *a: _block_round(*a, fj, wj, t, **full_stat),
                    *full_args, iters=it)
                m_act = _compact_bsr(m, hot)
                skip_args, skip_stat = _block_round_args(m_act)
                # true occupied-tile count (m_act is pow2-padded with
                # zero tiles purely for jit-cache sharing)
                n_active = int(np.isin(np.asarray(m.block_col), hot).sum())
                if verify_interpret and not verified:
                    _verify_depths(m, fj, wj, t, f, w, depths)
                    verified = True
                for depth in depths:
                    if on_tpu:
                        col_active = np.zeros(m.n_row_blocks, np.int32)
                        col_active[hot] = 1
                        caj = jnp.asarray(col_active)
                        ft = fj.reshape(-1, bs, c)
                        wt = (wj / t).reshape(-1, bs, 1)
                        skip_us = timeit(
                            lambda ft_, wt_: frontier_round_bsr_pallas(
                                m.blocks, m.block_row, m.block_col, caj,
                                ft_, wt_, m.n_row_blocks, bs=bs,
                                buffer_depth=depth),
                            ft, wt, iters=it)
                    elif depth == depths[0]:
                        skip_us = timeit(
                            lambda *a: _block_round(
                                *a, fj, wj, t, **skip_stat),
                            *skip_args, iters=it)
                    # else: off-TPU the oracle path is depth-invariant —
                    # the measurement from the first depth applies as-is
                    rows.append({
                        "n": n, "c": c, "density": d,
                        "buffer_depth": depth,
                        "n_edges": g.n_edges, "n_blocks": m.n_blocks,
                        "n_blocks_active": n_active,
                        "segment_sum_us": round(edge_us, 1),
                        "bsr_full_us": round(block_us, 1),
                        "pallas_skip_us": round(skip_us, 1),
                        "speedup_vs_segment_sum":
                            round(edge_us / skip_us, 3),
                    })
                print(f"[frontier] N=2^{int(np.log2(n))} C={c} d={d}: "
                      f"edge={edge_us/1e3:.1f}ms full={block_us/1e3:.1f}ms "
                      f"skip={skip_us/1e3:.1f}ms "
                      f"speedup={edge_us/skip_us:.2f}x")
    payload = annotate_payload({"meta": meta, "rows": rows})
    if out_path:
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"[frontier] wrote {out_path} ({len(rows)} rows)")
    return payload


def main():
    rng = np.random.default_rng(0)
    rows = []

    # diffusion: frontier push on a 20k-node power-law graph
    g = power_law_graph(20000, seed=1)
    p, b = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=128)
    x = jnp.asarray(rng.standard_normal(m.n_row_blocks * 128)
                    .astype(np.float32))
    us = timeit(lambda x: bsr_spmm(m, x, use_pallas=False), x)
    ai = 2 * g.n_edges / (m.n_blocks * 128 * 128 * 4)
    rows.append(("diffusion_bsr_ref_N20k", us,
                 f"edges={g.n_edges};blocks={m.n_blocks};ai={ai:.3f}"))

    # segment-sum: 1M edges x 64 feat
    e, d, s = 1_000_000, 64, 100_000
    seg = jnp.asarray(np.sort(rng.integers(0, s, e)).astype(np.int32))
    data = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))
    us = timeit(lambda a, b: segment_sum_ref(a, b, s), data, seg)
    rows.append(("segment_sum_ref_1Mx64", us,
                 f"bytes={e*d*4*2};gbps={e*d*4*2/us/1e3:.2f}"))

    # fm: criteo-shaped batch
    v = jnp.asarray(rng.standard_normal((65536, 39, 10)).astype(np.float32))
    us = timeit(fm_interaction_ref, v)
    rows.append(("fm_interaction_ref_B65536", us,
                 f"bytes={v.size*4};gbps={v.size*4/us/1e3:.2f}"))

    # attention: 1 head-group block
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 128))
                    .astype(np.float32) * 0.1)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 128))
                    .astype(np.float32) * 0.1)
    vv = jnp.asarray(rng.standard_normal((1, 2, 1024, 128))
                     .astype(np.float32))
    us = timeit(lambda q, k, v: attention_ref(q, k, v, causal=True),
                q, k, vv)
    fl = 4 * 8 * 1024 * 1024 * 128
    rows.append(("attention_ref_1x8x1024x128", us,
                 f"flops={fl};gflops={fl/us/1e3:.1f}"))

    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    import sys

    if "--sweep" in sys.argv:
        frontier_sweep()
    elif "--sweep-smoke" in sys.argv:
        frontier_sweep(ns=(2**12,), cs=(1, 2), densities=(1.0, 0.5),
                       iters=1, out_path="BENCH_kernels.smoke.json")
    else:
        main()
