"""Kernel microbenchmarks: wall time of the XLA reference paths on CPU
(the Pallas kernels are TPU-target and validated in interpret mode — CPU
interpret timings are not meaningful) + derived figures (bytes, flops,
arithmetic intensity) used in the roofline discussion.

Prints ``name,us_per_call,derived`` CSV as required.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pagerank_system, power_law_graph
from repro.kernels.attention import attention_ref
from repro.kernels.diffusion import bsr_spmm, prepare_bsr
from repro.kernels.fm import fm_interaction_ref
from repro.kernels.segment import segment_sum_ref


def timeit(fn, *args, iters=20):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    rng = np.random.default_rng(0)
    rows = []

    # diffusion: frontier push on a 20k-node power-law graph
    g = power_law_graph(20000, seed=1)
    p, b = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=128)
    x = jnp.asarray(rng.standard_normal(m.n_row_blocks * 128)
                    .astype(np.float32))
    us = timeit(lambda x: bsr_spmm(m, x, use_pallas=False), x)
    ai = 2 * g.n_edges / (m.n_blocks * 128 * 128 * 4)
    rows.append(("diffusion_bsr_ref_N20k", us,
                 f"edges={g.n_edges};blocks={m.n_blocks};ai={ai:.3f}"))

    # segment-sum: 1M edges x 64 feat
    e, d, s = 1_000_000, 64, 100_000
    seg = jnp.asarray(np.sort(rng.integers(0, s, e)).astype(np.int32))
    data = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))
    us = timeit(lambda a, b: segment_sum_ref(a, b, s), data, seg)
    rows.append(("segment_sum_ref_1Mx64", us,
                 f"bytes={e*d*4*2};gbps={e*d*4*2/us/1e3:.2f}"))

    # fm: criteo-shaped batch
    v = jnp.asarray(rng.standard_normal((65536, 39, 10)).astype(np.float32))
    us = timeit(fm_interaction_ref, v)
    rows.append(("fm_interaction_ref_B65536", us,
                 f"bytes={v.size*4};gbps={v.size*4/us/1e3:.2f}"))

    # attention: 1 head-group block
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 128))
                    .astype(np.float32) * 0.1)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 128))
                    .astype(np.float32) * 0.1)
    vv = jnp.asarray(rng.standard_normal((1, 2, 1024, 128))
                     .astype(np.float32))
    us = timeit(lambda q, k, v: attention_ref(q, k, v, causal=True),
                q, k, vv)
    fl = 4 * 8 * 1024 * 1024 * 128
    rows.append(("attention_ref_1x8x1024x128", us,
                 f"flops={fl};gflops={fl/us/1e3:.1f}"))

    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    main()
