"""Policy ablation: static vs dynamic cost tables per rebalancing policy.

Reproduces the paper's static-vs-dynamic protocol (Tables 1–3, §3.1) for
every :mod:`repro.balance` policy instead of only the §2.5.2 controller:
synthetic power-law graph, PageRank system (damping 0.85, ε = 0.15),
target error 1/N, K ∈ {2, 4, 8}, node order random or by out-degree
(the skewed order static partitions hate).  For each (K, order) cell the
table reports the normalized cost (``cost_iterations``) of:

  static          — no rebalancing (baseline)
  slope_ema       — paper §2.5.2 exact (through the control plane)
  cost_refresh    — periodic CB re-split from observed edge-op costs
  hysteresis      — slope-EMA + deadband + multi-move batching

Usage:
  PYTHONPATH=src python benchmarks/policy_ablation.py [--quick]

Outputs: results/policy_ablation/<order>.csv + a printed table.
"""
from __future__ import annotations

import argparse
import csv
import os
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    pagerank_system,
    power_law_graph,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                       "policy_ablation")

KS = (2, 4, 8)
POLICIES = (None, "slope_ema", "cost_refresh", "hysteresis")


def _cfg(k: int, n: int, policy, mode: str) -> SimulatorConfig:
    return SimulatorConfig(
        k=k, target_error=1.0 / n, eps=0.15, partition="uniform",
        policy=policy, dynamic=False, mode=mode, record_every=100,
        # cost_refresh balances observed work, not residual magnitude
        signal="edge-ops" if policy == "cost_refresh" else "residual",
    )


def run_ablation(order: str, n: int = 1000, seed: int = 0,
                 mode: str = "sequential", ks=KS, policies=POLICIES,
                 verbose: bool = True) -> Dict[Tuple, dict]:
    g = power_law_graph(n, alpha=1.5, seed=seed)
    if order == "out_degree":
        g = g.reorder(np.argsort(-g.out_degree(), kind="stable"))
    p, b = pagerank_system(g, damping=0.85)
    out: Dict[Tuple, dict] = {}
    for k in ks:
        for policy in policies:
            t0 = time.time()
            res = DistributedSimulator(p, b, _cfg(k, n, policy, mode)).run()
            out[(k, policy or "static")] = {
                "cost": res.cost_iterations,
                "moves": res.n_moves,
                "converged": res.converged,
                "steps": res.n_steps,
            }
            if verbose:
                print(f"  order={order} K={k} {policy or 'static':>12}: "
                      f"cost={res.cost_iterations:8.2f} "
                      f"moves={res.n_moves:3d} "
                      f"({time.time() - t0:.1f}s, conv={res.converged})")
    return out


def write_csv(table: Dict[Tuple, dict], path: str,
              policies=POLICIES) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    names = [p or "static" for p in policies]
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["K"] + [f"{nm}_{fld}" for nm in names
                            for fld in ("cost", "moves")])
        for k in sorted({key[0] for key in table}):
            row = [k]
            for nm in names:
                cell = table[(k, nm)]
                row += [f"{cell['cost']:.3f}", cell["moves"]]
            w.writerow(row)


def print_table(order: str, table: Dict[Tuple, dict],
                policies=POLICIES) -> None:
    names = [p or "static" for p in policies]
    print(f"\n[{order}] normalized cost (moves)")
    print("K   " + "".join(f"{nm:>22}" for nm in names))
    for k in sorted({key[0] for key in table}):
        cells = []
        for nm in names:
            c = table[(k, nm)]
            cells.append(f"{c['cost']:>15.2f} ({c['moves']:>3d})")
        print(f"{k:<4}" + "".join(cells))


def main(quick: bool = False):
    ks = (2, 4) if quick else KS
    n = 400 if quick else 1000
    tables = {}
    for order in ("random", "out_degree"):
        print(f"[policy_ablation] node order: {order}")
        t = run_ablation(order, n=n, ks=ks)
        write_csv(t, os.path.join(os.path.abspath(OUT_DIR),
                                  f"{order}.csv"))
        print_table(order, t)
        tables[order] = t
    return tables


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
